"""PrIM on a multi-bank mesh: the UPMEM execution model made visible.

MUST be launched fresh (sets the host-device count before jax init):

    PYTHONPATH=src python examples/prim_multibank.py

Runs three workloads with very different communication structures on an
8-bank mesh and prints their phase anatomy:
  RED       local reduce        -> one cross-bank tree      (tiny comm)
  SCAN-SSA  local scan          -> bank-sum exchange -> add (tiny comm)
  NW        wavefront: B+R-1 steps, a boundary column crosses banks
            EVERY step (the paper's worst-fit pattern, Takeaway 3)
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import prim  # noqa: E402
from repro.core.bank_parallel import BankGrid, make_bank_mesh  # noqa: E402


def main():
    grid = BankGrid(make_bank_mesh(8))
    print(f"bank mesh: {grid.n_banks} banks "
          "(DPU=device, MRAM=shard, exchanges=collectives)\n")
    key = jax.random.PRNGKey(0)

    for name, n, phases in [
        ("RED", 1 << 16, "local reduce + 1 tree exchange"),
        ("SCAN-SSA", 1 << 16, "local scan + bank-sum exchange + local add"),
        ("NW", 128, "wavefront: boundary handshake EVERY anti-diagonal"),
    ]:
        mod = prim.WORKLOADS[name]
        inputs = mod.make_inputs(n, key)
        t0 = time.perf_counter()
        got = mod.run_pim(grid, **inputs)
        jax.block_until_ready(got)
        dt = (time.perf_counter() - t0) * 1e3
        ok = all(
            bool(jnp.array_equal(jnp.asarray(g), jnp.asarray(w)))
            for g, w in zip(jax.tree.leaves(got),
                            jax.tree.leaves(mod.ref(**inputs))))
        c = mod.counts(n)
        print(f"{name:9s} n={n:6d}  correct={ok}  {dt:7.1f} ms "
              f"(first call, traced)")
        print(f"          phases: {phases}")
        print(f"          model: {c.bytes_streamed / 1e6:.1f} MB streamed, "
              f"{c.interbank_bytes / 1e3:.1f} KB inter-bank "
              f"({'suitable' if c.pim_suitable else 'NOT suitable'} "
              "per Fig. 4)\n")


if __name__ == "__main__":
    main()
