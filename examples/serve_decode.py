"""Serving example: continuous-batching decode — the paper's PIM pattern
applied to LM inference (DESIGN.md §4).

Runs a reduced model behind the ServeEngine: requests with skewed prompt
lengths share one batched KV cache (per-slot positions), new requests are
admitted as slots free up, and the decode step itself is the bank-parallel
workload (a batched GEMV against chip-resident weights).

With `--engine dispatch` BOTH serving phases route through the offload
planner instead of one fused jit: decode over the decode DAG and prefill
chunked over the prefill DAG (`--prefill-chunk` tokens per chunk), each
planned over {xeon, upmem_2556} with the KV cache bank-resident, and each
stage runs on its assigned device (host stages per-stage jit, PIM stages
as BankGrid phases) — same tokens, planner-chosen execution. The prefill
plan is optimized under the schedule-aware `overlapped` objective
(DESIGN.md §10).

    PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-3b]
    PYTHONPATH=src python examples/serve_decode.py --engine dispatch
    PYTHONPATH=src python examples/serve_decode.py --engine dispatch \
        --prefill-chunk 4 --show-schedule
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import Shardings, init_params
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b",
                    help="any assigned arch id (reduced config is used)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--engine", choices=("jit", "dispatch"), default="jit",
                    help="serving backend: fused jit, or planner-routed "
                         "hybrid dispatch for BOTH prefill and decode "
                         "(dense-attention archs only)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="dispatch engine: tokens per prefill chunk "
                         "(default: one chunk per prompt)")
    ap.add_argument("--show-schedule", action="store_true",
                    help="dispatch engine: print the executed timeline — "
                         "the launch groups the unified executor walks, "
                         "with serial/overlapped/pipelined wall-clocks "
                         "and per-resource busy/idle occupancy")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record the measured execution trace (per-slot "
                         "decode-step latencies; under --engine dispatch "
                         "also per-stage compute spans, channel "
                         "occupancy, FaceCache compile/cache-hit) and "
                         "write it as JSON, plus a Chrome trace_event "
                         "twin next to it (.chrome.json) for "
                         "chrome://tracing / Perfetto")
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    print(f"arch: {cfg.name} ({cfg.param_count() / 1e6:.1f}M reduced)")
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    dispatch_kwargs = ({"prefill_chunk": args.prefill_chunk}
                       if args.engine == "dispatch" else None)
    engine = ServeEngine(cfg, params, batch_slots=args.slots, max_len=96,
                         shd=shd, temperature=args.temperature, seed=7,
                         engine=args.engine,
                         dispatch_kwargs=dispatch_kwargs)

    def show(tag, p):
        devs = {}
        for dev in p.assignment.values():
            devs[dev] = devs.get(dev, 0) + 1
        print(f"{tag} plan [{p.method}, objective={p.objective}]: "
              f"{len(p.assignment)} stages -> "
              + ", ".join(f"{d}:{n}" for d, n in sorted(devs.items()))
              + f"; modeled {p.total_s * 1e3:.2f}ms/step at serving dims")

    def show_schedule(tag, step):
        from repro.dispatch.placement import evaluate
        from repro.dispatch.schedule import make_schedule
        # cost the executor's OWN assignment (includes any forced
        # overrides), so the timeline shown is the timeline executed
        sched = make_schedule(
            step.dag, evaluate(step.dag, step.executor.assignment),
            pipelined=True)
        print(f"\n{tag} executed timeline (the launch groups the unified "
              "executor walks, in order):")
        print(sched.render(max_groups=8))
        groups = step.executor.executed_order()
        run = " -> ".join(f"{dev}:{len(nodes)}" for dev, nodes in groups[:10])
        more = f" -> ... (+{len(groups) - 10} groups)" if len(groups) > 10 \
            else ""
        print(f"  executed group order: {run}{more}")

    if engine.dispatch_plan is not None:
        show("decode", engine.dispatch_plan)
    if engine.prefill_plan is not None:
        show("prefill", engine.prefill_plan)
    if args.show_schedule and args.engine == "dispatch":
        show_schedule("decode", engine._decode)
        show_schedule("prefill", engine._prefill_step)

    tracer = None
    if args.trace:
        from repro.dispatch.trace import Trace
        tracer = Trace(name=f"serve:{cfg.name}:{args.engine}")
        tracer.meta.update(arch=cfg.name, engine=args.engine,
                           slots=args.slots)
        if engine.dispatch_plan is not None:
            tracer.meta["assignment"] = dict(
                engine._decode.executor.assignment)
        engine.attach_tracer(tracer)

    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        plen = 4 + int(jax.random.randint(k, (), 0, 12))
        reqs.append(Request(i, jax.random.randint(
            k, (plen,), 0, cfg.vocab_size, dtype=jnp.int32), args.max_new))

    t0 = time.perf_counter()
    done = engine.serve(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid:2d} prompt[{len(r.prompt):2d}] "
              f"-> {len(r.out_tokens):2d} tokens: {r.out_tokens[:8]}...")
    print(f"\n{len(done)} requests, {n_tok} tokens, {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s, continuous batching over "
          f"{args.slots} slots)")

    if tracer is not None:
        chrome = (args.trace[:-5] if args.trace.endswith(".json")
                  else args.trace) + ".chrome.json"
        tracer.save(args.trace)
        tracer.save_chrome(chrome)
        steps = tracer.by_kind("decode_step")
        if steps:
            lat = sorted(e.dur_s for e in steps)
            print(f"trace: {len(tracer.events)} events "
                  f"({len(steps)} decode steps, median "
                  f"{lat[len(lat) // 2] * 1e3:.2f}ms/step) "
                  f"-> {args.trace} (+ {chrome})")


if __name__ == "__main__":
    main()
