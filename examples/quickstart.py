"""Quickstart: the paper's methodology end-to-end in ~60 seconds on CPU.

1. run a PrIM workload in the bank-parallel execution model,
2. characterize it with the three-term roofline + KT1-3 suitability,
3. reproduce the paper's headline Fig.-4 numbers from the calibrated model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import prim
from repro.core.bank_parallel import BankGrid, make_bank_mesh
from repro.core.hlo_analysis import analyze_hlo
from repro.core.perf_model import Figure4, compare
from repro.core.suitability import score


def main():
    # --- 1. a PrIM workload on the bank-parallel model ------------------
    grid = BankGrid(make_bank_mesh())
    mod = prim.WORKLOADS["SCAN-SSA"]
    inputs = mod.make_inputs(1 << 16, jax.random.PRNGKey(0))
    out = mod.run_pim(grid, **inputs)
    ok = bool(jnp.array_equal(out, mod.ref(**inputs)))
    print(f"SCAN-SSA on {grid.n_banks} bank(s): correct={ok}")

    # --- 2. characterize it (the paper's Key Takeaways as code) ---------
    compiled = jax.jit(mod.ref).lower(inputs["x"]).compile()
    an = analyze_hlo(compiled.as_text())
    rep = score(an, name="SCAN-SSA", machine="upmem_2556")
    for line in rep.takeaways:
        print(" ", line)
    print(f"  => PIM-suitable: {rep.pim_suitable}")

    # --- 3. the paper's headline comparison (calibrated model) ----------
    fig = Figure4([compare(c) for c in prim.all_ref_counts()])
    print(f"\n2556-DPU vs CPU : {fig.avg_speedup_2556_vs_cpu:5.1f}x "
          "(paper: 23.2x)")
    print(f"640-DPU  vs CPU : {fig.avg_speedup_640_vs_cpu:5.1f}x "
          "(paper: 10.1x)")
    print(f"2556-DPU vs GPU : {fig.avg_speedup_2556_vs_gpu_suitable:5.2f}x "
          "on the 10 suitable benchmarks (paper: 2.54x)")
    print(f"energy eff. 640 : {fig.avg_energy_eff_640_vs_cpu:5.2f}x "
          "(paper: 1.64x)")


if __name__ == "__main__":
    main()
