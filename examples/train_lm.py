"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps on CPU with the full production stack (budget ~20 min;
use --steps 20 for a quick look) — fault-tolerant loop,
atomic checkpoints, deterministic data pipeline, straggler tracking.

Interrupt it (Ctrl-C) and re-run: it resumes from the latest checkpoint and
reproduces the uninterrupted trajectory bit-for-bit.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax

from repro.configs.shapes import ShapeConfig
from repro.models import ModelConfig, Shardings
from repro.train import DataConfig, HParams, LoopConfig, TrainLoop


def make_100m() -> ModelConfig:
    """~100M params: a llama-style dense decoder scaled to CPU."""
    return ModelConfig(
        name="demo-100m", family="dense",
        n_layers=12, d_model=576, n_heads=8, n_kv_heads=4, d_ff=2304,
        vocab_size=32000, rope_theta=1e4, q_chunk=64, kv_chunk=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)  # ~6 s/step on CPU
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = make_100m()
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    shd = Shardings(None)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    loop = TrainLoop(
        cfg, shape, shd,
        HParams(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        LoopConfig(total_steps=args.steps, ckpt_every=50,
                   ckpt_dir=args.ckpt_dir, log_every=20),
        DataConfig(seed=1234))

    state = loop.resume_or_init()
    if state.step:
        print(f"resumed from checkpoint at step {state.step}")
    t0 = time.perf_counter()
    state = loop.run(state)
    dt = time.perf_counter() - t0

    for m in loop.metrics_log:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}")
    steps_run = args.steps - (state.step - args.steps)
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"\ndone: {state.step} steps in {dt:.0f}s "
          f"(~{tok_s:.0f} tok/s on CPU), "
          f"{len(loop.straggler_steps)} straggler steps flagged")
    first, last = loop.metrics_log[0]["loss"], loop.metrics_log[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
