"""Serving-gateway example: admission control + SLO-aware scheduling
above the continuous-batching ServeEngine (DESIGN.md §14).

Generates a seeded Poisson arrival stream over three priority classes
(interactive / standard / batch), pushes it through the bounded
admission queue, and drives the engine with stall-budgeted prefill
interleaving. Decode and prefill admissions are priced through the
planner-product `PlanCache` (keyed by batch signature) so the gateway
knows the cost of a prefill stall before it takes one; `--prewarm`
solves the whole signature envelope out of band first, which is what
keeps in-band tails flat.

With `--engine dispatch` both phases route through the offload planner
(dense-attention archs only), and `--trace` records the measured
timeline the PR-6 planner-fidelity gate can replay.

    PYTHONPATH=src python examples/gateway_serve.py
    PYTHONPATH=src python examples/gateway_serve.py --rate 16 --prewarm
    PYTHONPATH=src python examples/gateway_serve.py --engine dispatch \
        --prefill-chunk 4 --requests 6 --trace gw_trace.json
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import Shardings, init_params
from repro.serve import (PRIORITIES, Gateway, Request, ServeEngine,
                         poisson_requests)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b",
                    help="any assigned arch id (reduced config is used)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--queue-cap", type=int, default=8,
                    help="bounded admission-queue capacity")
    ap.add_argument("--policy", choices=("reject", "shed"), default="shed",
                    help="what to do when the queue is full: reject the "
                         "arrival, or shed the worst queued request for "
                         "a strictly higher-priority one")
    ap.add_argument("--engine", choices=("jit", "dispatch"), default="jit",
                    help="serving backend: fused jit, or planner-routed "
                         "hybrid dispatch for both phases")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="dispatch engine: tokens per prefill chunk")
    ap.add_argument("--prewarm", action="store_true",
                    help="price the full batch-signature envelope out of "
                         "band before serving (the production posture; "
                         "without it the first occurrence of each "
                         "signature pays its planner solve in band)")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record the measured execution trace and write "
                         "it as JSON plus a Chrome trace_event twin "
                         "(.chrome.json)")
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    print(f"arch: {cfg.name} ({cfg.param_count() / 1e6:.1f}M reduced)")
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    dispatch_kwargs = ({"prefill_chunk": args.prefill_chunk}
                       if args.engine == "dispatch" else None)
    engine = ServeEngine(cfg, params, batch_slots=args.slots, max_len=64,
                         shd=shd, engine=args.engine,
                         dispatch_kwargs=dispatch_kwargs)
    gw = Gateway(engine, queue_capacity=args.queue_cap,
                 shed_policy=args.policy, pos_bucket=16,
                 slo_ttft_s=0.5, slo_itl_s=0.25)

    prompt_lens = (4, 12)
    if args.prewarm:
        lens = range(prompt_lens[0], prompt_lens[1] + 1)
        warm = gw.prewarm(lens)
        print(f"prewarm: {warm['misses']} signature solves cached")
        if args.engine == "jit":
            # the jit engine's in-band cost is XLA tracing per prefill
            # shape, not planner solves — warm those traces too
            for i, plen in enumerate(lens):
                engine.serve([Request(-1 - i,
                                      jnp.ones((plen,), jnp.int32), 2)])
            print(f"prewarm: jit prefill traced for lens "
                  f"{lens.start}..{lens.stop - 1}")

    tracer = None
    if args.trace:
        from repro.dispatch.trace import Trace
        tracer = Trace(name=f"gateway:{cfg.name}:{args.engine}")
        tracer.meta.update(arch=cfg.name, engine=args.engine,
                           slots=args.slots, rate_rps=args.rate)
        gw.attach_tracer(tracer)

    reqs = poisson_requests(args.requests, args.rate, seed=args.seed,
                            vocab=cfg.vocab_size, prompt_lens=prompt_lens)
    stats = gw.run(reqs)

    for g in sorted(gw.finished, key=lambda g: g.rid):
        ttft = f"{g.ttft_s * 1e3:7.1f}" if g.ttft_s is not None else "      -"
        print(f"  req {g.rid:2d} [{PRIORITIES[g.priority]:11s}] "
              f"prompt[{len(g.prompt):2d}] -> {len(g.out_tokens):2d} tokens, "
              f"TTFT {ttft}ms")
    for g in sorted(gw.rejected, key=lambda g: g.rid):
        print(f"  req {g.rid:2d} [{PRIORITIES[g.priority]:11s}] "
              f"REJECTED ({g.reject_reason})")

    print()
    for metric, value in stats.rows():
        print(f"  {metric:22s} {value}")

    if tracer is not None:
        chrome = (args.trace[:-5] if args.trace.endswith(".json")
                  else args.trace) + ".chrome.json"
        tracer.save(args.trace)
        tracer.save_chrome(chrome)
        print(f"\ntrace: {len(tracer.events)} events -> {args.trace} "
              f"(+ {chrome})")


if __name__ == "__main__":
    main()
